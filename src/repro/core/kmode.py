"""k-mode clustering (Huang'98): k-means analogue under Hamming distance.

Used by the paper for ground-truth clustering on the full categorical data
and for clustering binary sketches (binary vectors are categorical with c=2).
Two engines share one control flow (`_kmedoids_run`), so they draw the
identical rng sequence and produce identical clusterings on the same
representation:

  * `kmode_packed` — the PRIMARY engine (DESIGN.md section 9): centres are
    member rows of a packed Cabin sketch matrix, every distance pass
    (seeding, assignment, medoid update) streams through the device-resident
    all-pairs engine (repro.core.allpairs), and the centre block lives on
    device, pow2-padded once with a traced valid count — no per-iteration
    reshape, O(log) compiled graphs across a whole run.  `batch_rows` turns
    on mini-batch mode for collections too large for full-batch medoid
    updates (the documented deviation — see `kmode_packed`).
  * `kmode_precomputed(dist_fn, ...)` — the host oracle: `dist_fn` returns
    dense distance matrices evaluated on host per pass.  Kept for arbitrary
    representations and as the bit-level equivalence reference the device
    engine is property-tested against (tests/test_cluster.py).

`kmode` is the NumPy host implementation over raw categorical matrices
(chunked Hamming distances, per-attribute mode centres) used for the paper's
full-data ground truth.  All entry points validate their arguments at the
API boundary and survive degenerate data (duplicate-heavy rows, k >= the
number of distinct rows, k > n) — the k-means++-style seeding falls back to
uniform sampling over not-yet-chosen rows when the min-distance vector
collapses to zero instead of crashing on an unnormalisable distribution.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np


def _check_args(n_rows: int, k: int, n_iter: int, what: str) -> None:
    """API-boundary validation shared by every entry point: the failure
    modes used to be an obscure `int(x.max())` ValueError on empty input
    and downstream shape errors for k = 0."""
    if k < 1:
        raise ValueError(f"{what}: k must be >= 1, got {k}")
    if n_iter < 1:
        raise ValueError(f"{what}: n_iter must be >= 1, got {n_iter}")
    if n_rows < 1:
        raise ValueError(f"{what}: cannot cluster an empty matrix (0 rows)")


def _seed_indices(n: int, k: int, rng: np.random.Generator,
                  dist_to: Callable[[int], np.ndarray]) -> np.ndarray:
    """k-means++-style medoid seeding over row indices.

    `dist_to(i)` returns the (n,) distances of every row to row i; the
    running min-distance vector d weights the next draw.  Already-chosen
    rows are excluded outright (their d is 0, but a concentrated float
    distribution could still return them under `rng.choice` — duplicate
    centres make a permanently dead cluster).  When d collapses to all
    zeros (duplicate-heavy data, or k >= #distinct rows) the draw falls
    back to UNIFORM over the not-yet-chosen rows — and over all rows once
    every row is already a centre, which only happens for k > n, where
    duplicate centres are unavoidable.  On non-degenerate data the drawn
    sequence is identical to the pre-fix seeding (chosen rows already
    carried zero probability), so fixed-seed comparisons across methods
    stay valid.
    """
    chosen = [int(rng.integers(n))]
    d = np.asarray(dist_to(chosen[0]), np.float64)
    for _ in range(1, k):
        p = np.maximum(d, 0.0)
        p[np.asarray(chosen)] = 0.0
        s = p.sum()
        if s > 0.0:
            idx = int(rng.choice(n, p=p / s))
        else:
            pool = np.setdiff1d(np.arange(n), np.asarray(chosen))
            if len(pool) == 0:
                pool = np.arange(n)
            idx = int(pool[rng.integers(len(pool))])
        chosen.append(idx)
        d = np.minimum(d, dist_to(idx))
    return np.asarray(chosen, np.int64)


def _hamming_to_centers(x: np.ndarray, centers: np.ndarray,
                        chunk: int = 512) -> np.ndarray:
    n, k = x.shape[0], centers.shape[0]
    out = np.empty((n, k), dtype=np.int32)
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        out[lo:hi] = (x[lo:hi, None, :] != centers[None, :, :]).sum(axis=2)
    return out


def _plusplus_init(x: np.ndarray, k: int, rng: np.random.Generator
                   ) -> np.ndarray:
    def dist_to(i: int) -> np.ndarray:
        return (x != x[i]).sum(axis=1).astype(np.float64)

    return x[_seed_indices(x.shape[0], k, rng, dist_to)]


def _modes(x: np.ndarray, labels: np.ndarray, k: int, n_cats: int,
           prev_centers: np.ndarray | None = None) -> np.ndarray:
    """Per-cluster per-attribute mode via a (n_attrs, n_cats) count table.

    An EMPTY cluster keeps its previous centre: the old all-zeros
    placeholder sat at the low-category corner of the space and captured
    low-category rows on the next assignment pass, silently reshaping the
    clustering around a centre no data ever elected."""
    n_attr = x.shape[1]
    centers = np.zeros((k, n_attr), dtype=x.dtype)
    cols = np.arange(n_attr)
    for c in range(k):
        members = x[labels == c]
        if len(members) == 0:
            if prev_centers is not None:
                centers[c] = prev_centers[c]
            continue
        table = np.zeros((n_attr, n_cats + 1), dtype=np.int32)
        for row in members:
            table[cols, row] += 1
        centers[c] = table.argmax(axis=1).astype(x.dtype)
    return centers


def kmode(
    x: np.ndarray,
    k: int,
    n_iter: int = 15,
    seed: int = 0,
    n_categories: int | None = None,
    n_init: int = 3,
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster rows of categorical matrix x into k clusters.

    Runs `n_init` k-means++-seeded restarts and keeps the one with the
    lowest within-cluster Hamming cost (standard restart practice; a single
    unlucky seeding otherwise dominates the comparison).
    Returns (labels (N,), centers (k, n_attrs)).
    """
    x = np.ascontiguousarray(x)
    if x.ndim != 2:
        raise ValueError(f"kmode: expected a 2-d matrix, got shape {x.shape}")
    _check_args(x.shape[0], k, n_iter, "kmode")
    if n_categories is None:
        n_categories = int(x.max())
    best = None
    for trial in range(max(n_init, 1)):
        rng = np.random.default_rng(seed * 1000 + trial)
        centers = _plusplus_init(x, k, rng)
        # -1 sentinel + closing assignment: same discipline as
        # _kmedoids_run — a genuinely all-zeros first assignment (k = 1)
        # must not read as convergence, and returned labels must be an
        # assignment against the RETURNED centres
        labels = np.full(x.shape[0], -1, dtype=np.int64)
        converged = False
        for _ in range(n_iter):
            dist = _hamming_to_centers(x, centers)
            new_labels = dist.argmin(axis=1)
            if np.array_equal(new_labels, labels):
                converged = True
                break
            labels = new_labels
            centers = _modes(x, labels, k, n_categories,
                             prev_centers=centers)
        if not converged:
            labels = _hamming_to_centers(x, centers).argmin(axis=1)
        cost = int(_hamming_to_centers(x, centers)[
            np.arange(x.shape[0]), labels].sum())
        if best is None or cost < best[0]:
            best = (cost, labels, centers)
    return best[1], best[2]


# ---------------------------------------------------------------------------
# k-medoids control flow shared by the device engine and the host oracle
# ---------------------------------------------------------------------------


class KmodeResult(NamedTuple):
    """Full clustering state from the medoid engines — what an ONLINE
    consumer (repro.cluster.ClusterIndex) needs to keep assigning rows
    after the fit: the labels, which rows were elected centres, and the
    centre rows themselves (host copies)."""

    labels: np.ndarray   # (n,) int64 cluster assignment per row
    medoids: np.ndarray  # (k,) int64 row index of each final centre
    centers: np.ndarray  # (k, repr_width) final centre rows


def _seed_and_install(n: int, k: int, seed: int,
                      dist_to: Callable[[int], np.ndarray],
                      set_center: Callable[[int, int], None]) -> np.ndarray:
    """Seed k medoids and install them as the initial centres — the one
    entry both the full-batch loop and the mini-batch sweep start from, so
    their rng draw sequences can never diverge."""
    rng = np.random.default_rng(seed)
    medoids = _seed_indices(n, k, rng, dist_to)
    for c in range(k):
        set_center(c, int(medoids[c]))
    return medoids


def _kmedoids_run(
    n: int,
    k: int,
    n_iter: int,
    seed: int,
    *,
    dist_to: Callable[[int], np.ndarray],
    set_center: Callable[[int, int], None],
    assign: Callable[[], np.ndarray],
    totals: Callable[[np.ndarray], np.ndarray],
) -> tuple[np.ndarray, np.ndarray]:
    """THE k-medoids loop: seeding, assignment sweeps, medoid updates —
    parameterised over a distance backend.  Both the device engine and the
    host oracle run exactly this function with the same rng, so equal
    per-pair distances imply bit-equal labels; the backends only decide
    WHERE the distance arithmetic happens.

    Empty clusters keep their current medoid (same rationale as `_modes`).
    Returns (labels (n,), medoids (k,)) with the guarantee that `labels`
    IS a one-shot assignment against the final medoids: convergence breaks
    before any further update, and an n_iter-exhausted run (whose last
    sweep updated the medoids after the last assignment) pays one closing
    assignment pass.  The pre-assignment label state is a -1 sentinel, so
    a first sweep that genuinely assigns every row to cluster 0 (always
    true for k = 1) still elects its medoids instead of being mistaken
    for convergence against the zero-initialised labels.
    """
    medoids = _seed_and_install(n, k, seed, dist_to, set_center)
    labels = np.full(n, -1, dtype=np.int64)
    converged = False
    for _ in range(n_iter):
        new_labels = assign()
        if np.array_equal(new_labels, labels):
            converged = True
            break
        labels = new_labels
        # medoid update: member minimising total distance to cluster members
        for c in range(k):
            members = np.flatnonzero(labels == c)
            if len(members) == 0:
                continue
            midx = int(members[int(np.argmin(totals(members)))])
            medoids[c] = midx
            set_center(c, midx)
    if not converged:
        labels = assign()  # consistent with the final medoids
    return labels, medoids


def kmode_packed(
    x_packed,
    k: int,
    *,
    d: int,
    n_iter: int = 15,
    seed: int = 0,
    metric: str = "cham",
    block: int = 2048,
    batch_rows: int | None = None,
    mode: str | None = None,
) -> KmodeResult:
    """k-medoids over PACKED Cabin sketches — the primary clustering engine.

    `x_packed` is an (n, d/32) int32 matrix of packed sketches; every
    distance pass streams through repro.core.allpairs under `metric`
    ("cham" = estimated categorical HD, "hamming" = exact sketch HD):
    assignment is a device-resident row-argmin against the centre block,
    medoid updates are streaming row-sums over device-gathered members —
    no (n, k) or (m, m) float matrix is ever built on host.  The centre
    block is allocated ONCE at the pow2 bucket of k and updated in place
    with the valid count traced (`argmin_rows(m_valid=k)`), so a whole run
    compiles O(log) graphs — one per pow2 member-bucket — rather than
    reshaping and re-uploading centres per iteration.

    Full-batch (`batch_rows=None`) produces labels bit-identical to the
    host oracle (`kmode_precomputed` with a dense `dist_fn` of the same
    metric) on the same rng sequence — including on degenerate inputs
    (all-duplicate rows, k >= #distinct rows, k > n), property-tested in
    tests/test_cluster.py.

    Mini-batch (`batch_rows=m`) is the DELIBERATE deviation for large n
    (DESIGN.md section 9.2): each sweep processes m-row slices, refreshing
    each touched centre from the slice's own members immediately after
    assigning the slice, so a medoid pass costs O(n * m / k) pair
    distances instead of O(n^2 / k); a final full assignment pass makes
    the returned labels consistent with the final centres.  Labels are NOT
    bit-identical to full-batch (centres see the data in slice order) —
    use it when n^2/k is the bottleneck, not when comparing estimators.
    """
    import jax.numpy as jnp  # local: keep the host paths numpy-only

    from repro.core import allpairs, packing

    x_dev = jnp.asarray(x_packed)
    if x_dev.ndim != 2:
        raise ValueError(
            f"kmode_packed: expected (n, d/32) packed rows, got {x_dev.shape}")
    n = x_dev.shape[0]
    _check_args(n, k, n_iter, "kmode_packed")
    if batch_rows is not None and batch_rows < 1:
        raise ValueError(
            f"kmode_packed: batch_rows must be >= 1, got {batch_rows}")

    # device-resident centre block: pow2-padded once, valid count traced
    kpad = packing.pow2_bucket(k)
    centers = jnp.zeros((kpad, x_dev.shape[1]), x_dev.dtype)
    medoid_rows = np.zeros(k, np.int64)

    def dist_to(i: int) -> np.ndarray:
        # distances of every row to row i: argmin over a 1-valid-row block
        _, vals = allpairs.argmin_rows(x_dev, x_dev[i][None, :], d=d,
                                       metric=metric, block=block, mode=mode)
        return vals

    def set_center(c: int, i: int) -> None:
        nonlocal centers
        medoid_rows[c] = i
        centers = centers.at[c].set(x_dev[i])

    def assign_rows(rows_dev) -> np.ndarray:
        lab, _ = allpairs.argmin_rows(rows_dev, centers, d=d, metric=metric,
                                      block=block, mode=mode, m_valid=k)
        return lab.astype(np.int64)

    def totals(members: np.ndarray) -> np.ndarray:
        sub = packing.padded_take(x_dev, members)
        out = allpairs.rowsum(sub, d=d, metric=metric, block=block, mode=mode,
                              m_valid=len(members))
        return out[: len(members)]

    if batch_rows is None:
        labels, medoids = _kmedoids_run(
            n, k, n_iter, seed, dist_to=dist_to, set_center=set_center,
            assign=lambda: assign_rows(x_dev), totals=totals)
    else:
        medoids = _seed_and_install(n, k, seed, dist_to, set_center)
        for _ in range(n_iter):
            for lo in range(0, n, batch_rows):
                hi = min(lo + batch_rows, n)
                lab = assign_rows(x_dev[lo:hi])
                # per-batch centre refresh: each touched centre re-elects
                # its medoid from THIS slice's members only
                for c in np.unique(lab):
                    members = lo + np.flatnonzero(lab == c)
                    midx = int(members[int(np.argmin(totals(members)))])
                    medoids[c] = midx
                    set_center(int(c), midx)
        labels = assign_rows(x_dev)  # consistent with the final centres
    return KmodeResult(labels, np.asarray(medoids, np.int64),
                       np.asarray(centers[:k]))


def kmode_precomputed(
    dist_fn,
    x_repr: np.ndarray,
    k: int,
    n_iter: int = 15,
    seed: int = 0,
    *,
    sketch_dim: int | None = None,
    metric: str = "cham",
    block: int = 2048,
    batch_rows: int | None = None,
    mode: str | None = None,
) -> np.ndarray:
    """k-medoids-flavoured variant: centres are member rows, assignment is
    nearest-centre under an estimated distance.  Returns labels (n,) int64.

    Two modes:

    * `sketch_dim` given — x_repr is a matrix of PACKED Cabin sketches
      (n, d/32) int32 and the run is delegated to `kmode_packed` (the
      device engine above); `dist_fn` is ignored and may be None.
      `metric` / `block` / `batch_rows` / `mode` pass through.

    * `sketch_dim` None — host-oracle mode: `dist_fn(a, b) -> (len(a),
      len(b))` distance matrix, evaluated on host per pass (kept for
      arbitrary representations and as the equivalence reference the
      device engine is pinned against).  `batch_rows` is not supported
      here: mini-batching is a deviation the ORACLE must not share, or
      the reference would drift with it.

    Both modes draw the identical rng sequence, so on the same
    representation they produce the same clustering.
    """
    n = np.shape(x_repr)[0]
    _check_args(n, k, n_iter, "kmode_precomputed")
    if sketch_dim is not None:
        return np.asarray(kmode_packed(
            x_repr, k, d=sketch_dim, n_iter=n_iter, seed=seed, metric=metric,
            block=block, batch_rows=batch_rows, mode=mode).labels)
    if dist_fn is None:
        raise ValueError(
            "kmode_precomputed: dist_fn is required without sketch_dim")
    if batch_rows is not None:
        raise ValueError("kmode_precomputed: batch_rows requires the packed "
                         "engine (pass sketch_dim=...)")

    x_repr = np.asarray(x_repr)
    centers = np.zeros((k,) + x_repr.shape[1:], dtype=x_repr.dtype)

    def dist_to(i: int) -> np.ndarray:
        return np.asarray(dist_fn(x_repr, x_repr[[i]]))[:, 0]

    def set_center(c: int, i: int) -> None:
        centers[c] = x_repr[i]

    def assign() -> np.ndarray:
        dist = np.asarray(dist_fn(x_repr, centers))
        return dist.argmin(axis=1).astype(np.int64)

    def totals(members: np.ndarray) -> np.ndarray:
        sub = np.asarray(dist_fn(x_repr[members], x_repr[members]))
        return sub.sum(axis=1)

    labels, _ = _kmedoids_run(n, k, n_iter, seed, dist_to=dist_to,
                              set_center=set_center, assign=assign,
                              totals=totals)
    return labels
