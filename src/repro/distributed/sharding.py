"""Sharding rules: logical activation constraints + path-based param specs.

Mesh convention (fixed by the production spec):
    single-pod:  (data=16, model=16)
    multi-pod:   (pod=2, data=16, model=16)

`DP_AXES` is ('pod', 'data') when the pod axis exists, else ('data',).

Parameter rules are path-based (MaxText-style): tree paths are matched by
the LAST matching rule key (substring match), so arch files never annotate
weights — the rules below encode TP (model axis on head/ffn dims), ZeRO-3 /
FSDP (data axis on the complementary dim) and EP (experts on model axis).
Stacked scan params get the leading layer axis unsharded automatically.
"""

from __future__ import annotations

import re

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------


def current_mesh() -> Mesh | None:
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or m.empty:
            return None
        return m
    except Exception:
        pass
    # jax < 0.5: no abstract-mesh API; the ambient mesh entered via
    # `with mesh:` lives in the legacy thread-resources env.
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def set_mesh(mesh: Mesh):
    """Context manager activating `mesh`: jax.sharding.set_mesh on new jax,
    the Mesh object itself (legacy global-mesh context) on jax < 0.5."""
    sm = getattr(jax.sharding, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map on new jax; jax.experimental.shard_map (check_rep) on
    jax < 0.5.  Only the kwargs this repo uses are forwarded."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    return legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=check_vma)


def mesh_axis_names() -> tuple[str, ...]:
    m = current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def mesh_devices(mesh: Mesh) -> list:
    """The mesh's devices as a flat list in mesh order — the per-shard
    placement the index partition layer keys on (shard s lives on
    ``mesh_devices(mesh)[s]``).  Abstract meshes (jax >= 0.5's
    get_abstract_mesh) carry no concrete devices; fall back to the process
    device list, which is what an abstract mesh of the whole host means."""
    devs = getattr(mesh, "devices", None)
    if devs is not None:
        return [d for d in np.asarray(devs).flat]
    return list(jax.devices())[: mesh.size]


def dp_axes() -> tuple[str, ...]:
    names = mesh_axis_names()
    return tuple(a for a in ("pod", "data") if a in names)


def constrain(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """with_sharding_constraint if a mesh is active; no-op otherwise.

    axes entries: None, an axis name, a tuple of names, or 'dp' which expands
    to the data-parallel axes present in the current mesh.
    """
    if current_mesh() is None:
        return x
    names = mesh_axis_names()

    def resolve(a):
        if a == "dp":
            got = dp_axes()
            return got if got else None
        if isinstance(a, tuple):
            kept = tuple(n for n in a if n in names)
            return kept if kept else None
        if a is not None and a not in names:
            return None
        return a

    spec = P(*[resolve(a) for a in axes])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# parameter partition rules (path substring -> PartitionSpec axes for the
# trailing dims; leading stacked/scan dims are padded with None)
# ---------------------------------------------------------------------------

# Order matters: later rules override earlier ones.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # default: replicate
    (r".*", ()),
    # embeddings: vocab on model (TP), d_model on data (FSDP)
    (r"embed/table", ("model", "data")),
    (r"lm_head", ("data", "model")),  # (D, V)
    (r"hashed_embed/table", ("model", "data")),
    # attention
    (r"attn/wq$", ("data", "model")),
    (r"attn/wk$", ("data", "model")),
    (r"attn/wv$", ("data", "model")),
    (r"attn/wo$", ("model", "data")),
    (r"attn/b[qkv]$", ("model",)),
    # MLA: lora ranks replicated-ish; big projections TP on head dim
    (r"attn/wq_a$", ("data", None)),
    (r"attn/wq_b$", (None, "model")),
    (r"attn/wkv_a$", ("data", None)),
    (r"attn/wkv_b$", (None, "model")),
    # dense mlp
    (r"mlp/w_gate$", ("data", "model")),
    (r"mlp/w_up$", ("data", "model")),
    (r"mlp/w_down$", ("model", "data")),
    # moe: experts on model (EP), FSDP on d_model dim
    (r"moe/router$", ("data", None)),
    (r"moe/w_gate$", ("model", "data", None)),
    (r"moe/w_up$", ("model", "data", None)),
    (r"moe/w_down$", ("model", None, "data")),
    (r"moe/shared/w_gate$", ("data", "model")),
    (r"moe/shared/w_up$", ("data", "model")),
    (r"moe/shared/w_down$", ("model", "data")),
    # mamba
    (r"mamba/in_proj$", ("data", "model")),
    (r"mamba/conv_w$", ("model", None)),
    (r"mamba/conv_b$", ("model",)),
    (r"mamba/x_proj$", ("model", None)),
    (r"mamba/dt_w$", (None, "model")),
    (r"mamba/dt_b$", ("model",)),
    (r"mamba/a_log$", ("model", None)),
    (r"mamba/d$", ("model",)),
    (r"mamba/out_proj$", ("model", "data")),
    # xlstm
    (r"lstm/w[qkvz]$", ("data", "model")),
    (r"lstm/w_up$", ("data", "model")),
    (r"lstm/w_z$", ("data", "model")),
    (r"lstm/w_down$", ("model", "data")),
    (r"lstm/w_[ifo]$", ("data", None)),
    (r"lstm/r_[zifo]$", ("model", None)),
    (r"lstm/wo$", ("model", "data")),
    (r"lstm/out_proj$", ("model", "data")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# When enabled (EXPERIMENTS.md section Perf, deepseek-v3 iteration 2), MoE
# expert weights are sharded over BOTH mesh axes on the expert dim — each
# chip holds whole experts (256 = 16x16), trading the per-layer ZeRO weight
# regather for the (smaller) token all-to-all.  Toggled per-run by dryrun
# --set moe_2d=true; falls back automatically when E doesn't divide.
_MOE_2D = False


def set_moe_2d(enabled: bool) -> None:
    global _MOE_2D
    _MOE_2D = bool(enabled)


def spec_for_path(path, leaf) -> P:
    s = _path_str(path)
    axes: tuple = ()
    for pattern, rule in _PARAM_RULES:
        if re.search(pattern, s):
            axes = rule
    if _MOE_2D and re.search(r"moe/w_(gate|up|down)$", s):
        # whole experts resident per chip: expert dim over (model, data)
        axes = (("model", "data"), None, None)
    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    if len(axes) > ndim:
        axes = axes[-ndim:] if ndim else ()
    pad = ndim - len(axes)
    full = (None,) * pad + tuple(axes)
    # drop axes that would not divide the dim evenly — GSPMD requires
    # divisibility for named sharding on weights we feed as in_shardings.
    mesh = current_mesh()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh is not None else {}

    def axis_size(ax) -> int:
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(ax, 1)

    cleaned = []
    for dim, ax in zip(getattr(leaf, "shape", (None,) * ndim), full):
        if ax is None:
            cleaned.append(None)
            continue
        size = axis_size(ax)
        if dim is None or size <= 1 or dim % size:
            cleaned.append(None)
        else:
            cleaned.append(ax)
    return P(*cleaned)


def param_specs(params) -> dict:
    """PartitionSpec tree mirroring a param tree."""
    return jax.tree_util.tree_map_with_path(spec_for_path, params)


def param_shardings(mesh: Mesh, params):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for_path(path, leaf)), params
    )


def batch_spec(ndim: int) -> P:
    """Batch-leading activation spec: (dp, None, ...)."""
    got = dp_axes()
    lead = got if got else None
    return P(lead, *([None] * (ndim - 1)))


def batch_sharding_for(mesh, shape: tuple[int, ...]):
    """NamedSharding for a batch-leading array, dropping the dp axes when the
    batch dim doesn't divide them (e.g. long_500k's global_batch=1)."""
    from jax.sharding import NamedSharding

    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    total = 1
    for a in axes:
        total *= sizes[a]
    lead = axes if (axes and shape and shape[0] % total == 0) else None
    return NamedSharding(mesh, P(lead, *([None] * (len(shape) - 1))))


# ---------------------------------------------------------------------------
# decode-cache sharding rules
#
# Cache entries are stacked (n_repeat, batch, ...).  Strategy per entry:
#   * GQA K/V (R, B, H, S, dh): heads on 'model' when H divides it, else
#     SEQUENCE-sharded cache (flash-decode style partial softmax combine);
#     batch on dp when divisible.
#   * MLA latent (R, B, S, r): sequence on 'model' (no head dim by design).
#   * SSM / LSTM states: feature dims on 'model' where divisible.
# The divisibility cleanup below auto-drops axes that don't divide (e.g.
# batch=1 for long_500k replicates instead of failing).
# ---------------------------------------------------------------------------

_CACHE_RULES: list[tuple[str, tuple]] = [
    (r".*", ()),
    (r"mixer/[kv]$", ("dp", "model", None, None)),        # (B,H,S,dh) heads
    (r"mixer/[kv]_scale$", ("dp", "model", None, None)),
    (r"mixer/c_kv$", ("dp", "model", None)),               # (B,S,r) seq
    (r"mixer/c_scale$", ("dp", "model", None)),
    (r"mixer/k_rope$", ("dp", "model", None)),
    (r"mixer/conv$", ("dp", None, "model")),               # (B,K-1,ED)
    (r"mixer/ssm$", ("dp", "model", None)),                # (B,ED,N)
    (r"mixer/c$", ("dp", None, "model", None)),            # mlstm (B,H,dh,dh)
    (r"mixer/n$", ("dp", None, "model")),
    (r"mixer/m$", ("dp", None)),
    (r"mixer/h$", ("dp", "model")),                        # slstm (B,d)
]


def cache_spec_for_path(path, leaf, kv_heads: int | None = None) -> P:
    s = _path_str(path)
    axes: tuple = ()
    for pattern, rule in _CACHE_RULES:
        if re.search(pattern, s):
            axes = rule
    mesh = current_mesh()
    names = mesh_axis_names()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes)) if mesh is not None else {}
    model_size = sizes.get("model", 1)
    # GQA fallback: if the head dim doesn't divide 'model', shard SEQ instead.
    if re.search(r"mixer/[kv](_scale)?$", s) and kv_heads is not None:
        if model_size > 1 and kv_heads % model_size:
            axes = ("dp", None, "model", None)
    ndim = leaf.ndim
    resolved = []
    for a in axes:
        if a == "dp":
            got = dp_axes()
            resolved.append(got if got else None)
        elif a is not None and a not in names:
            resolved.append(None)
        else:
            resolved.append(a)
    pad = ndim - len(resolved)
    full = [None] * pad + resolved
    cleaned = []
    for dim, ax in zip(leaf.shape, full):
        if ax is None:
            cleaned.append(None)
            continue
        if isinstance(ax, tuple):
            size = 1
            for a in ax:
                size *= sizes.get(a, 1)
        else:
            size = sizes.get(ax, 1)
        if size <= 1 or dim % size:
            cleaned.append(None)
        else:
            cleaned.append(ax)
    return P(*cleaned)


def cache_specs(caches, kv_heads: int | None = None):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec_for_path(p, l, kv_heads), caches)
