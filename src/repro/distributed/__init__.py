"""Distributed substrate."""
