"""Optional pipeline parallelism: 1F1B-style microbatch rotation via
shard_map + collective_permute over a dedicated 'stage' mesh axis.

The 40-cell dry-run matrix uses DP/FSDP/TP/SP/EP (DESIGN.md section 5); PP is
provided as a composable feature for depth-dominated models on meshes where
a stage axis is carved out of the data axis (e.g. (stage=4, data=4,
model=16)).  The implementation here is the GPipe-schedule special case
expressed with jax.lax collectives:

  * the layer stack is split into S stages; stage s holds its own params;
  * a shard_map over the 'stage' axis runs, per rotation step, the local
    stage on the activation block it currently holds, then
    collective_permute's activations to the next stage;
  * M >= S microbatches flow through; total steps = S + M - 1 (bubble
    fraction (S-1)/(S+M-1), reported by `bubble_fraction`).

Lowering this under the production mesh is exercised by
tests/test_distributed.py (4-stage mesh over forced host devices) and the
dryrun --set pipeline_stages=N path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages + n_microbatches - 1)


def pipeline_apply(mesh: Mesh, stage_fn, stage_params, x_microbatches,
                   axis: str = "stage"):
    """Run a GPipe rotation.

    stage_fn(params, x) -> x  : one stage's forward on one microbatch.
    stage_params          : pytree whose leaves have a leading stage dim
                            (sharded over `axis`).
    x_microbatches        : (M, mb, ...) microbatched activations, all
                            resident on stage 0's shard initially.
    Returns (M, mb, ...) outputs as produced by the LAST stage.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1) ; xs: (M, mb, ...)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        stage_idx = jax.lax.axis_index(axis)
        xs = xs[0]  # (M, mb, ...) local copy
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        total = n_stages + m - 1

        def body(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others use what arrived
            use_inject = jnp.logical_and(stage_idx == 0, t < m)
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.minimum(t, m - 1), axis=0, keepdims=False)
            cur = jnp.where(use_inject, inject, buf)
            y = stage_fn(params, cur)
            # last stage records its output for microbatch (t - S + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            record = jnp.logical_and(stage_idx == n_stages - 1,
                                     t >= n_stages - 1)
            outs = jax.lax.cond(
                record,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, axis=0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, total, body, (buf, outs))
        return outs[None]  # restore stage-leading dim

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,
    )
    # replicate microbatches across stages (each stage only *uses* its turn)
    xs_tiled = jnp.broadcast_to(x_microbatches[None],
                                (n_stages,) + x_microbatches.shape)
    outs = fn(stage_params, xs_tiled)
    return outs[-1]  # last stage's recorded outputs
