"""Overload-tolerant serving front door for the sketch index.

`FrontDoor` wraps a live `repro.index.QueryEngine` and turns it from a
single-caller library into something that survives concurrent bursty
traffic (DESIGN.md section 12):

  * **Coalescing** — concurrent `topk`/`radius`/`assign` requests are
    grouped by (op, parameter, input layout) and flushed as ONE engine
    call, so they ride the engine's existing pow2 micro-batch buckets
    and O(log N) compile-cache discipline instead of each paying a solo
    dispatch.  `assign` is served as top-1 and coalesces with `topk(k=1)`.
  * **Deadline-aware flush** — a partially-filled batch flushes when it
    fills, when the oldest member has waited `max_wait_ms`, or at
    `oldest_deadline - service_estimate` (EWMA per op, seeded from the
    same observations that feed the obs latency histograms), whichever
    comes first.
  * **Admission control / backpressure** — a bounded two-class queue
    (interactive vs bulk) rejects excess load at the door with
    `RejectedError` carrying a retry-after derived from the observed
    drain rate; bulk is shed before interactive (serve.admission).
  * **Graceful degradation** — a request's deadline propagates into the
    banded top-k walk as a band-expansion budget: rather than blocking
    its batch, an over-deadline request gets back the best candidates
    found in budget with `partial=True` and the residual certificate
    gap (the DESIGN.md 8.4 exactness certificate, reported instead of
    silently broken).  `partial=False` answers are bit-identical to the
    synchronous engine's.
  * **Fault tolerance** — enqueue/flush/publish are faultinject crash
    points; flush-side failures retry with bounded exponential backoff,
    and a set-once result latch per request guarantees every admitted
    request is answered exactly once (no loss, no double answers) even
    when the chaos harness kills a flush mid-flight.

The front door is layout-agnostic: a `shard(mesh)`-ed engine (DESIGN.md
section 13) serves the same bits through the same `topk_packed` /
`radius_packed` entry points, so coalescing, admission, deadlines, and
the partial-answer contract all work unchanged over a sharded engine.

Threading model: callers admit from any thread; ONE dispatcher thread
owns the engine's query path (the engine itself stays single-threaded —
the front door is the serialization point).  Engine mutations
(add/remove/migrate) keep the same single-writer discipline as before;
interleave them through quiesced windows, not concurrently with serving.

Every decision — admit, reject, shed, timeout, retry, partial — is
recorded in `repro.obs` under `frontdoor_*` instruments; invariant
counters (`answered`, `double_answers`) are additionally plain fields so
chaos tests can assert them under REPRO_OBS=0.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro import obs
from repro.runtime import faultinject
from repro.serve.admission import (CLASS_BULK, CLASS_INTERACTIVE, CLASSES,
                                   AdmissionQueue, RejectedError)
from repro.serve.deadline import Deadline, ServiceEstimator

__all__ = ["FrontDoor", "ServeResult", "Request", "RejectedError",
           "FrontDoorClosed", "Deadline",
           "CLASS_INTERACTIVE", "CLASS_BULK"]

_CP_ENQUEUE = faultinject.declare("frontdoor.enqueue")
_CP_FLUSH = faultinject.declare("frontdoor.flush")
_CP_PUBLISH = faultinject.declare("frontdoor.publish")

_OPS = ("topk", "radius", "assign")


class FrontDoorClosed(RuntimeError):
    """submit() after close(): the door no longer accepts work."""


@dataclass
class ServeResult:
    """One request's answer.

    topk: `ids` (rows, k') / `dists` (rows, k'); a partial answer can
    leave slots unfilled (id -1, dist inf).  assign: `ids`/`dists` are
    (rows,).  radius: `hits` is a list of per-query id arrays.

    `partial=True` means the deadline stopped the band walk before the
    8.4 exactness certificate closed; `cert_gap` is the residual gap
    (0.0 on exact answers, inf when the budget ran out before k
    candidates were even seen).  `timed_out` marks answers degraded by
    an expired deadline (admission-time expiry or radius-at-flush);
    `error` carries the terminal exception when retries were exhausted.
    """

    ids: np.ndarray | None = None
    dists: np.ndarray | None = None
    hits: list | None = None
    partial: bool = False
    cert_gap: float = 0.0
    timed_out: bool = False
    error: BaseException | None = None
    queued_ms: float = 0.0
    service_ms: float = 0.0
    latency_ms: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None


class Request:
    """Handle for one admitted request: a set-once result latch.

    `resolve` is idempotent by construction (first caller wins, later
    calls are counted, not applied) — the exactly-once answer guarantee
    under retries hangs on this.
    """

    __slots__ = ("op", "cls", "queries", "fmt", "rows", "param", "deadline",
                 "t_submit", "t_flush", "_event", "_result", "_lock")

    def __init__(self, op, cls, queries, fmt, rows, param, deadline):
        self.op = op
        self.cls = cls
        self.queries = queries
        self.fmt = fmt
        self.rows = rows
        self.param = param
        self.deadline = deadline
        self.t_submit = time.monotonic()
        self.t_flush = None
        self._event = threading.Event()
        self._result: ServeResult | None = None
        self._lock = threading.Lock()

    @property
    def key(self):
        """Coalescing key: requests sharing it can flush as one engine
        call.  assign rides the topk(k=1) bucket."""
        op = "topk" if self.op == "assign" else self.op
        return (op, self.param, self.fmt)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def resolve(self, result: ServeResult) -> bool:
        """Latch `result` if unanswered; False (and no effect) if a
        result was already published."""
        with self._lock:
            if self._result is not None:
                return False
            result.latency_ms = (time.monotonic() - self.t_submit) * 1e3
            if self.t_flush is not None:
                result.queued_ms = (self.t_flush - self.t_submit) * 1e3
            self._result = result
        self._event.set()
        return True

    def result(self, timeout: float | None = None) -> ServeResult:
        """Block until the answer is published.  Admitted requests are
        always answered (worst case: an error result after retries or at
        close); `timeout` is the caller's own patience, raising
        TimeoutError without consuming the eventual answer."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"{self.op} request not answered "
                               f"within {timeout}s")
        return self._result


@dataclass
class _Group:
    """One coalesced flush in the making."""

    key: tuple
    members: list = field(default_factory=list)

    @property
    def rows(self) -> int:
        return sum(m.rows for m in self.members)


class FrontDoor:
    """Thread-safe serving facade over a `QueryEngine` (see module doc).

    Parameters
    ----------
    engine : repro.index.QueryEngine
        The wrapped engine.  The front door becomes the only caller of
        its query path.
    interactive_limit / bulk_limit / bulk_headroom :
        Admission bounds (serve.admission.AdmissionQueue).
    max_batch_rows : flush when a coalesced group reaches this many
        query rows (snap it to the engine's pow2 buckets).
    max_wait_ms : max time the oldest member of a group waits for
        coalescing company before flushing anyway.
    default_service_ms / safety : service-estimate prior and the margin
        factor applied when comparing a deadline against the estimate.
    max_retries / backoff_ms : bounded exponential-backoff retry for
        flush-side failures (attempt i sleeps backoff_ms * 2**i).
    """

    def __init__(self, engine, *, interactive_limit: int = 256,
                 bulk_limit: int = 256, bulk_headroom: float = 0.5,
                 max_batch_rows: int = 64, max_wait_ms: float = 2.0,
                 default_service_ms: float = 20.0, safety: float = 1.25,
                 max_retries: int = 3, backoff_ms: float = 1.0,
                 registry=None):
        if max_batch_rows < 1:
            raise ValueError("max_batch_rows must be >= 1")
        self.engine = engine
        self.obs = engine.obs if registry is None else registry
        self.queue = AdmissionQueue(
            interactive_limit=interactive_limit, bulk_limit=bulk_limit,
            bulk_headroom=bulk_headroom, registry=self.obs)
        self.estimator = ServiceEstimator(default_ms=default_service_ms)
        self.max_batch_rows = int(max_batch_rows)
        self.max_wait_s = max_wait_ms / 1e3
        self.safety = float(safety)
        self.max_retries = int(max_retries)
        self.backoff_s = backoff_ms / 1e3
        # invariant counters: plain fields (live under REPRO_OBS=0) that
        # the chaos/soak assertions read; obs counters mirror them
        self.answered = 0
        self.double_answers = 0
        self._n_lock = threading.Lock()

        reg = self.obs
        self._c_answered = {c: reg.counter("frontdoor_answered_total", cls=c)
                            for c in CLASSES}
        self._c_timeout = {c: reg.counter("frontdoor_timeouts_total", cls=c)
                           for c in CLASSES}
        self._c_partial = {c: reg.counter("frontdoor_partials_total", cls=c)
                           for c in CLASSES}
        self._c_retries = reg.counter("frontdoor_retries_total")
        self._c_faults = reg.counter("frontdoor_faults_total")
        self._c_double = reg.counter("frontdoor_double_answers_total")
        self._c_flushes = reg.counter("frontdoor_flushes_total")
        self._h_rows = reg.histogram("frontdoor_flush_rows")
        self._h_wait = reg.histogram("frontdoor_queue_wait_ms")
        self._h_service = {op: reg.histogram("frontdoor_service_ms", op=op)
                           for op in ("topk", "radius")}
        self._h_e2e = {c: reg.histogram("frontdoor_latency_ms", cls=c)
                       for c in CLASSES}

        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="frontdoor-dispatch")
        self._thread.start()

    # -- caller side ---------------------------------------------------------

    def submit(self, op: str, queries, *, k: int | None = None,
               r: float | None = None, cls: str = CLASS_INTERACTIVE,
               timeout_ms: float | None = None, deadline=None) -> Request:
        """Admit a request; returns its `Request` handle immediately.

        Raises `RejectedError` (backpressure — NOT admitted, safe to
        retry after `retry_after_s`) or `FrontDoorClosed`.  `timeout_ms`
        builds a `Deadline` relative to now; pass `deadline` directly
        for an absolute one.  A deadline already expired at admission is
        answered on the spot with an empty partial result — it is never
        enqueued (zero-timeout contract: `timeout_ms=0` is an explicit
        "only if free" probe)."""
        if not self._running:
            raise FrontDoorClosed("front door is closed")
        if op not in _OPS:
            raise ValueError(f"op must be one of {_OPS}, got {op!r}")
        if cls not in CLASSES:
            raise ValueError(f"cls must be one of {CLASSES}, got {cls!r}")
        queries, fmt, rows = self._normalize(queries)
        if op == "radius":
            if r is None:
                raise ValueError("radius requires r")
            param: object = float(r)
        else:
            param = 1 if op == "assign" else int(k if k is not None else 10)
            if param < 0:
                raise ValueError(f"k must be >= 0, got {param}")
        if deadline is None and timeout_ms is not None:
            deadline = Deadline(timeout_ms)
        req = Request(op, cls, queries, fmt, rows, param, deadline)
        if rows == 0:
            # empty batch: answer inline (trivially exact), nothing to
            # coalesce — mirrors the engine's own empty fast path
            self._publish(req, self._empty_result(req, partial=False))
            return req
        if deadline is not None and deadline.expired:
            self._c_timeout[cls].inc()
            self._publish(req, self._empty_result(req, partial=True,
                                                  timed_out=True))
            return req
        faultinject.crash_point(_CP_ENQUEUE)
        self.queue.offer(req)  # RejectedError propagates to the caller
        return req

    def topk(self, queries, k: int = 10, **kw) -> ServeResult:
        return self.submit("topk", queries, k=k, **kw).result()

    def radius(self, queries, r: float, **kw) -> ServeResult:
        return self.submit("radius", queries, r=r, **kw).result()

    def assign(self, queries, **kw) -> ServeResult:
        """Nearest stored id per query (top-1), coalesced with topk(1)."""
        return self.submit("assign", queries, **kw).result()

    # -- request plumbing ----------------------------------------------------

    def _normalize(self, queries):
        """-> (queries, fmt, rows).  Dense rows stay (rows, n_dims)
        arrays; COO pairs become (indices, values) int arrays.  Shape
        errors surface here, at submit, not on the dispatcher thread."""
        if isinstance(queries, (tuple, list)):
            idx, val = queries
            idx = np.asarray(idx)
            val = np.asarray(val)
            if idx.ndim != 2 or idx.shape != val.shape:
                raise ValueError("COO input needs matching (rows, m) "
                                 "indices/values")
            return (idx, val), "coo", idx.shape[0]
        x = np.asarray(queries)
        if x.ndim != 2:
            raise ValueError(f"expected dense (rows, n_dims), got {x.shape}")
        return x, "dense", x.shape[0]

    def _empty_result(self, req: Request, *, partial: bool,
                      timed_out: bool = False,
                      error: BaseException | None = None) -> ServeResult:
        gap = float("inf") if partial else 0.0
        if req.op == "radius":
            return ServeResult(hits=[np.zeros(0, np.int64)] * req.rows,
                               partial=partial, cert_gap=gap,
                               timed_out=timed_out, error=error)
        if req.op == "assign":
            return ServeResult(ids=np.full(req.rows, -1, np.int64),
                               dists=np.full(req.rows, np.inf, np.float32),
                               partial=partial, cert_gap=gap,
                               timed_out=timed_out, error=error)
        return ServeResult(ids=np.zeros((req.rows, 0), np.int64),
                           dists=np.zeros((req.rows, 0), np.float32),
                           partial=partial, cert_gap=gap,
                           timed_out=timed_out, error=error)

    def _publish(self, req: Request, res: ServeResult) -> None:
        if req.resolve(res):
            with self._n_lock:
                self.answered += 1
            self._c_answered[req.cls].inc()
            self._h_e2e[req.cls].observe(res.latency_ms)
            if res.partial:
                self._c_partial[req.cls].inc()
        else:
            with self._n_lock:
                self.double_answers += 1
            self._c_double.inc()

    # -- dispatcher ----------------------------------------------------------

    def _loop(self) -> None:
        while True:
            members = self.queue.take_group(self.max_batch_rows)
            if members is None:
                return  # closed and drained
            group = _Group(members[0].key, members)
            try:
                self._fill_window(group)
                self._flush(group)
            except BaseException as e:  # includes InjectedCrash leaks
                # the dispatcher must survive anything: answer the
                # still-unanswered members with an error result rather
                # than orphaning them (only this thread resolves admitted
                # requests, so `done` cannot flip under us here)
                for m in group.members:
                    if not m.done:
                        self._publish(m, self._empty_result(
                            m, partial=True, error=e))
            self.queue.note_drained(len(group.members))

    def _flush_due(self, group: _Group, now: float) -> float:
        """Earliest of: oldest arrival + max_wait, any member's
        deadline minus the (safety-scaled) service estimate."""
        due = min(m.t_submit for m in group.members) + self.max_wait_s
        op = group.key[0]
        est_s = self.estimator.estimate_ms(op) / 1e3 * self.safety
        for m in group.members:
            d = m.deadline
            if d is None:
                continue
            rem = (d.remaining_s() if hasattr(d, "remaining_s")
                   else (0.0 if d.expired else None))
            if rem is not None:
                due = min(due, now + rem - est_s)
        return due

    def _fill_window(self, group: _Group) -> None:
        """Hold a non-full group briefly so arrivals can coalesce —
        bounded by batch-fill, max_wait, and member deadlines."""
        while group.rows < self.max_batch_rows:
            now = time.monotonic()
            due = self._flush_due(group, now)
            if now >= due:
                return
            self.queue.wait_for_arrival(min(due - now, 0.005))
            self.queue.collect_matching(group.members, group.key,
                                        self.max_batch_rows)

    def _flush(self, group: _Group) -> None:
        """Partition by deadline pressure, run, publish.

        Members whose remaining budget clears the service estimate run
        as one EXACT batch (bit-identical to the synchronous engine);
        the rest share a budgeted call under the tightest deadline, so a
        straggler degrades to a certified-partial answer instead of
        dragging exact traffic past its own deadlines."""
        t_flush = time.monotonic()
        for m in group.members:
            m.t_flush = t_flush
            self._h_wait.observe((t_flush - m.t_submit) * 1e3)
        self._c_flushes.inc()
        self._h_rows.observe(group.rows)
        op = group.key[0]
        est_s = self.estimator.estimate_ms(op) / 1e3 * self.safety
        exact, budgeted = [], []
        for m in group.members:
            d = m.deadline
            if d is None:
                exact.append(m)
            else:
                rem = (d.remaining_s() if hasattr(d, "remaining_s")
                       else (0.0 if d.expired else est_s + 1.0))
                (exact if rem > est_s else budgeted).append(m)
        if exact:
            self._run_members(group.key, exact, deadline=None)
        if budgeted:
            if op == "radius":
                # radius has no budgeted walk: run members still inside
                # their deadline exactly, time out the already-expired
                live = [m for m in budgeted if not m.deadline.expired]
                for m in budgeted:
                    if m not in live:
                        self._c_timeout[m.cls].inc()
                        self._publish(m, self._empty_result(
                            m, partial=True, timed_out=True))
                if live:
                    self._run_members(group.key, live, deadline=None)
            else:
                tightest = min(budgeted, key=self._remaining).deadline
                self._run_members(group.key, budgeted, deadline=tightest)

    @staticmethod
    def _remaining(m: Request) -> float:
        d = m.deadline
        return (d.remaining_s() if hasattr(d, "remaining_s")
                else (0.0 if d.expired else float("inf")))

    def _run_members(self, key, members: list, deadline) -> None:
        """One engine call for `members`, with crash points, bounded
        retry, and exactly-once publication."""
        op, param, fmt = key
        queries = self._concat([m.queries for m in members], fmt)
        attempt = 0
        out = None
        err: BaseException | None = None
        while True:
            try:
                with obs.span("frontdoor.flush", op=op,
                              rows=sum(m.rows for m in members)):
                    faultinject.crash_point(_CP_FLUSH)
                    t0 = time.perf_counter()
                    out = self._call_engine(op, param, queries, deadline)
                    service_ms = (time.perf_counter() - t0) * 1e3
                    faultinject.crash_point(_CP_PUBLISH)
                err = None
                break
            except (Exception, faultinject.InjectedCrash) as e:
                self._c_faults.inc()
                err = e
                if attempt >= self.max_retries:
                    break
                # a member may have expired during the failed attempt;
                # budgeted members re-run under the same deadline object,
                # so the retry sees the truth, not a stale snapshot
                self._c_retries.inc()
                time.sleep(self.backoff_s * (2 ** attempt))
                attempt += 1
        if err is not None:
            for m in members:
                self._publish(m, self._empty_result(m, partial=True,
                                                    error=err))
            return
        self.estimator.observe("topk" if op == "assign" else op, service_ms)
        self._h_service["topk" if op == "assign" else op].observe(service_ms)
        self._distribute(op, members, out)

    def _concat(self, parts: list, fmt: str):
        if len(parts) == 1:
            return parts[0]
        if fmt == "dense":
            return np.concatenate(parts, axis=0)
        width = max(p[0].shape[1] for p in parts)

        def padw(a):
            return np.pad(a, ((0, 0), (0, width - a.shape[1])))

        return (np.concatenate([padw(p[0]) for p in parts], axis=0),
                np.concatenate([padw(p[1]) for p in parts], axis=0))

    def _call_engine(self, op: str, param, queries, deadline):
        if op == "radius":
            return self.engine.radius(queries, param), None
        if deadline is None:
            ids, dists = self.engine.topk(queries, param)
            return (ids, dists), {"partial": False, "cert_gap": 0.0}
        ids, dists, info = self.engine.topk_budgeted(queries, param,
                                                     deadline=deadline)
        return (ids, dists), info

    def _distribute(self, op: str, members: list, out) -> None:
        payload, info = out
        partial = bool(info["partial"]) if info is not None else False
        gap = float(info["cert_gap"]) if info is not None else 0.0
        lo = 0
        for m in members:
            hi = lo + m.rows
            if op == "radius":
                res = ServeResult(hits=payload[lo:hi])
            else:
                ids, dists = payload[0][lo:hi], payload[1][lo:hi]
                if m.op == "assign":
                    if ids.shape[1] == 0:  # empty store: nothing to assign
                        ids = np.full(m.rows, -1, np.int64)
                        dists = np.full(m.rows, np.inf, np.float32)
                    else:
                        ids, dists = ids[:, 0].copy(), dists[:, 0].copy()
                res = ServeResult(ids=ids, dists=dists, partial=partial,
                                  cert_gap=gap)
            if partial:
                res.timed_out = m.deadline is not None and m.deadline.expired
            self._publish(m, res)
            lo = hi

    # -- lifecycle -----------------------------------------------------------

    def stats(self) -> dict:
        return {
            "queue_depth": {c: self.queue.depth(c) for c in CLASSES},
            "drain_rate": self.queue.drain_rate(),
            "service_estimate_ms": self.estimator.snapshot(),
            "answered": self.answered,
            "double_answers": self.double_answers,
        }

    def close(self, timeout: float = 10.0) -> None:
        """Stop admitting, drain already-admitted requests, stop the
        dispatcher.  Idempotent."""
        self._running = False
        self.queue.close()
        self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
