"""Serving substrate."""
