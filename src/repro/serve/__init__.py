"""Serving substrate.

`frontdoor` is the overload-tolerant facade over the index
`QueryEngine` (admission control, deadline-aware micro-batching,
graceful degradation — DESIGN.md section 12); `engine` is the LM
decode serving engine.
"""

from repro.serve.admission import (CLASS_BULK, CLASS_INTERACTIVE,
                                   AdmissionQueue, RejectedError)
from repro.serve.deadline import Deadline, ServiceEstimator
from repro.serve.frontdoor import (FrontDoor, FrontDoorClosed, Request,
                                   ServeResult)

__all__ = [
    "AdmissionQueue", "CLASS_BULK", "CLASS_INTERACTIVE", "Deadline",
    "FrontDoor", "FrontDoorClosed", "RejectedError", "Request",
    "ServeResult", "ServiceEstimator",
]
