"""Serving engine: batched prefill + decode with per-family caches.

`make_serve_step(cfg, pcfg)` builds the jitted one-token step used by the
decode dry-run shapes (decode_32k / long_500k): inputs are (params, caches,
tokens (B,1), pos) and outputs (logits, new_caches).  The engine adds a
minimal batched request loop on top (greedy / temperature sampling) for the
runnable examples; real deployments would front this with continuous
batching — the step function is the part that must be production-shaped.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig, pcfg: ParallelConfig):
    def serve_step(params, caches, tokens, pos, enc_out=None):
        logits, new_caches = T.decode_step(cfg, params, caches, tokens, pos,
                                           pcfg, enc_out=enc_out)
        return logits, new_caches

    return serve_step


@dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, n_generated)
    steps: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 pcfg: ParallelConfig = ParallelConfig(), jit: bool = True):
        self.cfg, self.pcfg, self.params = cfg, pcfg, params
        fn = make_serve_step(cfg, pcfg)
        self.step_fn = jax.jit(fn, donate_argnums=(1,)) if jit else fn

    def generate(self, prompts: jnp.ndarray, max_new: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 frontend: jnp.ndarray | None = None) -> GenerationResult:
        """prompts: (B, S) int32 (same length per batch for simplicity)."""
        b, s = prompts.shape
        batch = {"tokens": prompts}
        enc_out = None
        if self.cfg.kind == "encdec":
            batch["frontend"] = frontend
            enc_out = T._run_encoder(self.cfg, self.params, frontend, self.pcfg)
        elif self.cfg.frontend is not None and frontend is not None:
            batch["frontend"] = frontend
        logits, caches = T.prefill(self.cfg, self.params, batch, max_len,
                                   self.pcfg, self.pcfg.kv_cache_dtype)
        offset = 0
        if self.cfg.frontend is not None and self.cfg.kind != "encdec" \
                and frontend is not None:
            offset = self.cfg.n_frontend_tokens
        key = jax.random.PRNGKey(seed)
        last = logits[:, -1, :]
        out = []
        tok = None
        for i in range(max_new):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, last / temperature, axis=-1)
            else:
                tok = jnp.argmax(last, axis=-1)
            tok = tok.astype(jnp.int32)[:, None]
            out.append(np.asarray(tok))
            pos = jnp.int32(offset + s + i)
            logits_step, caches = self.step_fn(self.params, caches, tok, pos,
                                               enc_out)
            last = logits_step[:, 0, :]
        return GenerationResult(tokens=np.concatenate(out, axis=1),
                                steps=max_new)
