"""Deadlines and service-time estimation for the serving front door.

A `Deadline` is a point on the monotonic clock; everything downstream
(admission, flush planning, the budgeted band walk in
`core.allpairs.topk_rows_banded`) only ever asks two questions of it —
`expired` and `remaining_s()` — so tests can substitute any object with
those attributes to script knife-edge timings (e.g. "expires after the
second band round") without sleeping.

`ServiceEstimator` keeps a per-op EWMA of observed flush service times.
The front door uses it to answer "if I flush now, when will the result
land?" — the flush trigger is `oldest_deadline - estimate`, so the
estimate must exist even before the first flush (a configurable prior)
and must keep working under REPRO_OBS=0, where the obs histograms are
null and quantiles are NaN.  When obs is live, the same observations
also feed the `frontdoor.service_ms` histogram, so the EWMA and the
histogram never disagree about what was measured — they are two views
of one stream.
"""

from __future__ import annotations

import threading
import time


class Deadline:
    """A monotonic-clock deadline.

    Construct with a relative budget (`Deadline(timeout_ms=5.0)`) or an
    absolute instant on the same clock (`Deadline(at=t)`).  `clock` is
    injectable for tests; it must be monotonic and in seconds.
    """

    __slots__ = ("t", "clock")

    def __init__(self, timeout_ms: float | None = None, *,
                 at: float | None = None, clock=time.monotonic):
        if (timeout_ms is None) == (at is None):
            raise ValueError("pass exactly one of timeout_ms / at")
        self.clock = clock
        self.t = float(at) if at is not None else clock() + timeout_ms / 1e3

    @property
    def expired(self) -> bool:
        return self.clock() >= self.t

    def remaining_s(self) -> float:
        """Seconds until expiry; negative once past it."""
        return self.t - self.clock()

    def remaining_ms(self) -> float:
        return self.remaining_s() * 1e3

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Deadline(remaining_ms={self.remaining_ms():.3f})"


class ServiceEstimator:
    """Per-op EWMA of flush service time, in milliseconds.

    Starts from a conservative prior (`default_ms`) so the very first
    flush decision is already deadline-aware; `alpha` trades tracking
    speed against noise (per-flush service time is lumpy because batch
    sizes snap to pow2 buckets).  Thread-safe: observed from the
    dispatcher thread, read from caller threads for retry-after hints.
    """

    def __init__(self, default_ms: float = 20.0, alpha: float = 0.25):
        if default_ms <= 0:
            raise ValueError("default_ms must be positive")
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.default_ms = float(default_ms)
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._ewma: dict[str, float] = {}

    def observe(self, op: str, service_ms: float) -> None:
        if service_ms < 0:
            return
        with self._lock:
            prev = self._ewma.get(op)
            if prev is None:
                self._ewma[op] = float(service_ms)
            else:
                self._ewma[op] = prev + self.alpha * (service_ms - prev)

    def estimate_ms(self, op: str) -> float:
        with self._lock:
            return self._ewma.get(op, self.default_ms)

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            return dict(self._ewma)
