"""Bounded two-class admission queue with explicit backpressure.

The front door admits requests into per-class bounded queues
("interactive" and "bulk") instead of letting callers pile work onto
the engine.  Overload therefore surfaces as a typed `RejectedError` at
the door — with a retry-after hint derived from the observed drain
rate — rather than as unbounded queueing and latency collapse inside
the process.

Shedding policy (bulk before interactive):
  * interactive is admitted while its own queue has room;
  * bulk is admitted only while its own queue has room AND interactive
    occupancy is below `bulk_headroom * interactive_limit`.  As
    interactive pressure rises, bulk is the first traffic turned away,
    long before interactive requests see a full queue.

Dispatch order mirrors the policy: `take_group` always prefers an
interactive leader, so queued bulk work also yields the engine to
interactive work.  Every admit/reject decision is counted in
`repro.obs` (`frontdoor_admitted_total`, `frontdoor_rejected_total`
with a `reason` label distinguishing capacity rejections from policy
sheds), and queue depths are live gauges.
"""

from __future__ import annotations

import threading
import time
from collections import deque

from repro.obs.registry import NULL_REGISTRY

CLASS_INTERACTIVE = "interactive"
CLASS_BULK = "bulk"
CLASSES = (CLASS_INTERACTIVE, CLASS_BULK)

# sliding window (seconds) over which the drain rate is measured for
# retry-after hints; short enough to track load shifts, long enough to
# smooth over individual flushes
_DRAIN_WINDOW_S = 5.0
_RETRY_AFTER_MIN_S = 0.01
_RETRY_AFTER_MAX_S = 5.0
# hint when nothing has drained yet (cold start / stalled engine)
_RETRY_AFTER_DEFAULT_S = 0.1


class RejectedError(RuntimeError):
    """Backpressure: the request was NOT admitted and will never be
    answered.  `retry_after_s` is the door's estimate of when capacity
    will exist, derived from current depth over the observed drain
    rate; `reason` is "full" (the class queue is at its limit), "shed"
    (bulk turned away to protect interactive headroom), or "closed"."""

    def __init__(self, cls: str, reason: str, retry_after_s: float):
        self.cls = cls
        self.reason = reason
        self.retry_after_s = retry_after_s
        super().__init__(
            f"{cls} request rejected ({reason}); "
            f"retry after {retry_after_s:.3f}s")


class AdmissionQueue:
    """Bounded FIFO per class, one condition variable for the
    dispatcher.  All state transitions happen under a single lock; the
    lock is never held across engine work."""

    def __init__(self, *, interactive_limit: int = 256,
                 bulk_limit: int = 256, bulk_headroom: float = 0.5,
                 registry=None):
        if interactive_limit < 1 or bulk_limit < 0:
            raise ValueError("queue limits must be positive")
        if not 0.0 < bulk_headroom <= 1.0:
            raise ValueError("bulk_headroom must be in (0, 1]")
        self.limits = {CLASS_INTERACTIVE: int(interactive_limit),
                       CLASS_BULK: int(bulk_limit)}
        # interactive occupancy at/above which bulk is shed outright
        self._shed_bar = max(1, int(bulk_headroom * interactive_limit))
        self.cond = threading.Condition()
        self._q: dict[str, list] = {c: [] for c in CLASSES}
        self._closed = False
        self._drained: deque = deque()  # (t_monotonic, n) drain events

        reg = NULL_REGISTRY if registry is None else registry
        self._c_admit = {c: reg.counter("frontdoor_admitted_total", cls=c)
                         for c in CLASSES}
        self._c_reject = {
            (c, why): reg.counter("frontdoor_rejected_total",
                                  cls=c, reason=why)
            for c in CLASSES for why in ("full", "shed", "closed")}
        for c in CLASSES:
            reg.gauge_fn("frontdoor_queue_depth",
                         lambda c=c: float(len(self._q[c])), cls=c)
        reg.gauge_fn("frontdoor_drain_rate", self.drain_rate)

    # ---- caller side -------------------------------------------------
    def offer(self, req) -> None:
        """Admit `req` or raise `RejectedError`.  Never blocks."""
        cls = req.cls
        with self.cond:
            if self._closed:
                self._reject(cls, "closed", 0.0)
            depth_i = len(self._q[CLASS_INTERACTIVE])
            if cls == CLASS_INTERACTIVE:
                if depth_i >= self.limits[cls]:
                    self._reject(cls, "full", self._retry_after(depth_i))
            else:
                depth_b = len(self._q[CLASS_BULK])
                if depth_b >= self.limits[cls]:
                    self._reject(cls, "full", self._retry_after(depth_b))
                if depth_i >= self._shed_bar:
                    # shed bulk before interactive: interactive pressure
                    # has eaten bulk's headroom
                    self._reject(cls, "shed", self._retry_after(depth_i))
            self._q[cls].append(req)
            self._c_admit[cls].inc()
            self.cond.notify_all()

    def _reject(self, cls: str, reason: str, retry_after_s: float):
        self._c_reject[(cls, reason)].inc()
        raise RejectedError(cls, reason, retry_after_s)

    # ---- dispatcher side ---------------------------------------------
    def take_group(self, max_rows: int):
        """Block until work exists (or the queue is closed AND empty —
        then None).  Pops an interactive-preferred leader plus every
        queued request sharing its coalesce key, up to `max_rows` total
        query rows, preserving per-class FIFO order."""
        with self.cond:
            while not (self._q[CLASS_INTERACTIVE] or self._q[CLASS_BULK]):
                if self._closed:
                    return None
                self.cond.wait(0.05)
            if self._q[CLASS_INTERACTIVE]:
                lead = self._q[CLASS_INTERACTIVE].pop(0)
            else:
                lead = self._q[CLASS_BULK].pop(0)
            group = [lead]
            self._collect_locked(group, lead.key, max_rows)
            return group

    def collect_matching(self, group: list, key, max_rows: int) -> int:
        """Non-blocking top-up of an in-flight group with newly arrived
        requests sharing `key`.  Returns how many were added."""
        with self.cond:
            return self._collect_locked(group, key, max_rows)

    def _collect_locked(self, group: list, key, max_rows: int) -> int:
        added = 0
        rows = sum(r.rows for r in group)
        for cls in CLASSES:  # interactive first
            keep = []
            for r in self._q[cls]:
                if r.key == key and rows + r.rows <= max_rows:
                    group.append(r)
                    rows += r.rows
                    added += 1
                else:
                    keep.append(r)
            self._q[cls] = keep
        return added

    def wait_for_arrival(self, timeout_s: float) -> None:
        with self.cond:
            if not (self._q[CLASS_INTERACTIVE] or self._q[CLASS_BULK]):
                self.cond.wait(max(0.0, timeout_s))

    def note_drained(self, n: int, now: float | None = None) -> None:
        """Record that `n` requests left the queue and were answered —
        feeds the drain rate behind retry-after hints."""
        t = time.monotonic() if now is None else now
        with self.cond:
            self._drained.append((t, n))
            cutoff = t - _DRAIN_WINDOW_S
            while self._drained and self._drained[0][0] < cutoff:
                self._drained.popleft()

    def drain_rate(self) -> float:
        """Observed drain rate, requests/second over the recent window."""
        t = time.monotonic()
        with self.cond:
            cutoff = t - _DRAIN_WINDOW_S
            total = sum(n for ts, n in self._drained if ts >= cutoff)
        return total / _DRAIN_WINDOW_S

    def _retry_after(self, depth: int) -> float:
        rate = self.drain_rate()
        if rate <= 0.0:
            return _RETRY_AFTER_DEFAULT_S
        return min(_RETRY_AFTER_MAX_S,
                   max(_RETRY_AFTER_MIN_S, (depth + 1) / rate))

    # ---- lifecycle ---------------------------------------------------
    def depth(self, cls: str | None = None) -> int:
        with self.cond:
            if cls is not None:
                return len(self._q[cls])
            return sum(len(q) for q in self._q.values())

    def close(self) -> None:
        """Stop admitting; already-admitted requests stay queued for the
        dispatcher to drain (no acked request is dropped at shutdown)."""
        with self.cond:
            self._closed = True
            self.cond.notify_all()
